"""Full Causal Mask attention as a Pallas kernel (paper §II-C, Table IV/V).

Grid over query blocks; K/V stream fully into VMEM per step. This is the
quadratic baseline — the simulator shows it spilling its N×N score matrix
out of the 4 MB scratchpad at long context (the 96.7 %-stall row of
Table V); here we only care that the numerics match the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, block_q: int):
    i = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * scale
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    scores = q @ k.T  # (block_q, N)
    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    probs = common.row_softmax_masked(scores, kpos <= qpos)
    o_ref[...] = (probs @ v).astype(o_ref.dtype)


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """softmax(QK^T / sqrt(d) + M) V for q, k, v : (N, d)."""
    n, d = q.shape
    bq = common.q_block(n)
    assert n % bq == 0, f"context {n} must be a multiple of the query block {bq}"
    kernel = functools.partial(_kernel, scale=1.0 / (d**0.5), block_q=bq)
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=common.INTERPRET,
    )(q, k, v)
