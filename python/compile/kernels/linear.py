"""Causal linear attention as a chunked Pallas kernel.

phi(x) = elu(x P) + 1 with a low-rank projection P : (d, r) — the paper's
"kernel function = low-rank projections". Causality is handled with the
standard chunked decomposition:

    y_chunk = intra(chunk)                (C×C masked, quadratic in C only)
            + phi(q_chunk) @ S            (inter-chunk recurrent state, r×d)

The (r, d) state S and (r,) normalizer z are carried across chunks by a
``jax.lax.scan`` at L2 — each scan step is one ``pallas_call``. This is the
O(d) persistent-state end of the paper's memory-state tradeoff (Fig 1): the
NPU keeps only S/z resident in scratchpad instead of an O(N·d) KV cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

CHUNK = 128  # one systolic tile of rows per chunk


def _phi(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    h = x @ proj
    return jnp.where(h > 0, h + 1.0, jnp.exp(h))


def _chunk_kernel(q_ref, k_ref, v_ref, p_ref, s_ref, z_ref, o_ref, s_out_ref, z_out_ref):
    """One chunk step: consume state (S, z), emit outputs and next state."""
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)  # (r, d) inter-chunk KV state
    z = z_ref[...].astype(jnp.float32)  # (1, r) inter-chunk normalizer
    pq = _phi(q, p)  # (C, r)
    pk = _phi(k, p)  # (C, r)
    c = q.shape[0]
    # Intra-chunk causal part: A[i,j] = pq_i . pk_j for j <= i.
    a = pq @ pk.T
    qpos = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    a = jnp.where(kpos <= qpos, a, 0.0)
    num = a @ v + pq @ s
    zc = jnp.cumsum(pk, axis=0)  # within-chunk normalizer prefix
    den = jnp.sum(pq * (zc + z), axis=-1, keepdims=True)
    o_ref[...] = (num / den).astype(o_ref.dtype)
    s_out_ref[...] = (s + pk.T @ v).astype(s_out_ref.dtype)
    z_out_ref[...] = (z + jnp.sum(pk, axis=0, keepdims=True)).astype(z_out_ref.dtype)


def _chunk_step(q, k, v, proj, s, z):
    c, d = q.shape
    r = proj.shape[1]
    full = lambda *shape: pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))
    return pl.pallas_call(
        _chunk_kernel,
        grid=(),
        in_specs=[full(c, d), full(c, d), full(c, d), full(d, r), full(r, d), full(1, r)],
        out_specs=[full(c, d), full(r, d), full(1, r)],
        out_shape=[
            jax.ShapeDtypeStruct((c, d), q.dtype),
            jax.ShapeDtypeStruct((r, d), jnp.float32),
            jax.ShapeDtypeStruct((1, r), jnp.float32),
        ],
        interpret=common.INTERPRET,
    )(q, k, v, proj, s, z)


def linear_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, proj: jnp.ndarray
) -> jnp.ndarray:
    """Chunked causal linear attention for q, k, v : (N, d), proj : (d, r)."""
    n, d = q.shape
    r = proj.shape[1]
    chunk = min(CHUNK, n)
    assert n % chunk == 0, f"context {n} must be a multiple of the chunk {chunk}"
    m = n // chunk
    qc = q.reshape(m, chunk, d)
    kc = k.reshape(m, chunk, d)
    vc = v.reshape(m, chunk, d)

    def step(carry, xs):
        s, z = carry
        qi, ki, vi = xs
        o, s2, z2 = _chunk_step(qi, ki, vi, proj, s, z)
        return (s2, z2), o

    s0 = jnp.zeros((r, d), jnp.float32)
    z0 = jnp.zeros((1, r), jnp.float32)
    (_, _), out = jax.lax.scan(step, (s0, z0), (qc, kc, vc))
    return out.reshape(n, d)
