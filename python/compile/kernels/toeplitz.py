"""Band-limited Toeplitz structured attention as a Pallas kernel.

W[i,j] = gamma^|i-j| has constant diagonals, so weights decay geometrically
off the main diagonal; the kernel therefore computes only a sliding window
of ``band`` keys per query (paper §V: the diagonal structure maps onto the
systolic array "Cannon-style" with static control flow). Compute is
O(N · band · d) — this is what gives Toeplitz its near-linear row in
Table III.

Each query block loads one (band + block_q)-tall K/V window with a dynamic
but statically-sized slice, so the VMEM working set is independent of N.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    scale: float,
    log_gamma: float,
    block_q: int,
    band: int,
    window: int,
    n: int,
):
    i = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * scale
    # Sliding K/V window: ends at the last row of this query block. The slice
    # start is dynamic, the extent static (window), so the schedule is
    # compile-time fixed — the "static control flow" property of §V.
    start = jnp.clip(i * block_q + block_q - window, 0, n - window)
    kw = pl.load(k_ref, (pl.ds(start, window), slice(None))).astype(jnp.float32)
    vw = pl.load(v_ref, (pl.ds(start, window), slice(None))).astype(jnp.float32)
    scores = q @ kw.T  # (block_q, window)
    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    kpos = start + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = (kpos <= qpos) & (qpos - kpos < band)
    delta = jnp.abs(qpos - kpos).astype(jnp.float32)
    scores = scores * jnp.where(mask, jnp.exp(delta * log_gamma), 0.0)
    probs = common.row_softmax_masked(scores, mask)
    o_ref[...] = (probs @ vw).astype(o_ref.dtype)


def toeplitz_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    band: int = 128,
    gamma: float = 0.9,
) -> jnp.ndarray:
    """Banded Toeplitz attention for q, k, v : (N, d); band in positions."""
    n, d = q.shape
    bq = common.q_block(n)
    assert n % bq == 0, f"context {n} must be a multiple of the query block {bq}"
    window = min(band + bq, n)
    kernel = functools.partial(
        _kernel,
        scale=1.0 / (d**0.5),
        log_gamma=math.log(gamma),
        block_q=bq,
        band=band,
        window=window,
        n=n,
    )
    return pl.pallas_call(
        kernel,
        grid=(n // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=common.INTERPRET,
    )(q, k, v)
