"""L1 Pallas kernels for the five causal inference operators (paper §II-C).

Public entry points (all take ``(N, d)`` arrays, return ``(N, d)``):

- :func:`causal.causal_attention`       — Full Causal Mask (quadratic baseline)
- :func:`retentive.retentive_attention` — Retentive decay
- :func:`toeplitz.toeplitz_attention`   — band-limited Toeplitz
- :func:`linear.linear_attention`       — chunked causal linear (low-rank phi)
- :func:`fourier.fourier_attention`     — frequency-domain product

``ref`` holds the pure-jnp oracles each kernel is tested against.
"""

from .causal import causal_attention
from .retentive import retentive_attention
from .toeplitz import toeplitz_attention
from .linear import linear_attention
from .fourier import fourier_attention

__all__ = [
    "causal_attention",
    "retentive_attention",
    "toeplitz_attention",
    "linear_attention",
    "fourier_attention",
]
