"""L2 JAX model: attention layers + a small transformer block per operator.

This is the build-time compute-graph layer of the three-layer stack. Every
function here is pure JAX calling the L1 Pallas kernels in ``kernels/``;
``aot.py`` lowers the jitted functions once to HLO text, and the Rust
runtime (L3) executes them through PJRT — Python never runs on the request
path.

Two artifact families are produced:

- **operator artifacts** — a single-head causal operator ``(N, d_h)`` →
  ``(N, d_h)``; these are the microbenchmark subjects of paper §III.
- **block artifacts** — a pre-norm transformer block (MHA with a pluggable
  causal operator + MLP), the unit the serving example drives end-to-end.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import (
    causal_attention,
    fourier_attention,
    linear_attention,
    retentive_attention,
    toeplitz_attention,
)

# Paper defaults (§III-A): head dim 64, decay factors, Toeplitz band, and the
# low-rank feature dimension d_state = 16 (§III-E sweeps it to 128).
D_HEAD = 64
D_STATE = 16
RETENTIVE_GAMMA = 0.97
TOEPLITZ_GAMMA = 0.9
TOEPLITZ_BAND = 128

OPERATOR_NAMES = ("causal", "retentive", "toeplitz", "linear", "fourier")


def _linear_proj(d: int, d_state: int) -> jnp.ndarray:
    """Fixed (seeded) low-rank projection for linear attention's phi."""
    rng = np.random.RandomState(7)
    return jnp.asarray(rng.randn(d, d_state) * (1.0 / np.sqrt(d)), jnp.float32)


def attention_op(
    name: str, d: int = D_HEAD, d_state: int = D_STATE
) -> Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """Return the single-head operator ``fn(q, k, v) -> y`` for ``name``.

    Operator hyper-parameters (decay rates, band, projection) are baked in
    as compile-time constants so each artifact is self-contained.
    """
    if name == "causal":
        return causal_attention
    if name == "retentive":
        return functools.partial(retentive_attention, gamma=RETENTIVE_GAMMA)
    if name == "toeplitz":
        return functools.partial(
            toeplitz_attention, band=TOEPLITZ_BAND, gamma=TOEPLITZ_GAMMA
        )
    if name == "linear":
        proj = _linear_proj(d, d_state)
        return lambda q, k, v: linear_attention(q, k, v, proj)
    if name == "fourier":
        return fourier_attention
    raise ValueError(f"unknown operator {name!r}; expected one of {OPERATOR_NAMES}")


def make_operator_fn(name: str, d: int = D_HEAD, d_state: int = D_STATE):
    """Jittable single-head operator for AOT lowering: (q, k, v) -> (y,)."""
    op = attention_op(name, d, d_state)

    def fn(q, k, v):
        return (op(q, k, v),)

    return fn


# ---------------------------------------------------------------------------
# Transformer block
# ---------------------------------------------------------------------------


def init_block_params(
    seed: int, d_model: int, n_heads: int, d_ff: int
) -> dict[str, jnp.ndarray]:
    """Seeded block parameters (served weights are fixed per artifact)."""
    rng = np.random.RandomState(seed)

    def w(*shape):
        return jnp.asarray(rng.randn(*shape) * (1.0 / np.sqrt(shape[0])), jnp.float32)

    return {
        "wq": w(d_model, d_model),
        "wk": w(d_model, d_model),
        "wv": w(d_model, d_model),
        "wo": w(d_model, d_model),
        "w1": w(d_model, d_ff),
        "b1": jnp.zeros((d_ff,), jnp.float32),
        "w2": w(d_ff, d_model),
        "b2": jnp.zeros((d_model,), jnp.float32),
        "ln1_g": jnp.ones((d_model,), jnp.float32),
        "ln1_b": jnp.zeros((d_model,), jnp.float32),
        "ln2_g": jnp.ones((d_model,), jnp.float32),
        "ln2_b": jnp.zeros((d_model,), jnp.float32),
    }


def _layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def multi_head_attention(
    x: jnp.ndarray, params: dict, op_name: str, n_heads: int
) -> jnp.ndarray:
    """MHA with a pluggable causal operator: heads run under vmap so each
    head lowers to the same Pallas kernel schedule."""
    n, d_model = x.shape
    d_h = d_model // n_heads
    q = (x @ params["wq"]).reshape(n, n_heads, d_h).transpose(1, 0, 2)
    k = (x @ params["wk"]).reshape(n, n_heads, d_h).transpose(1, 0, 2)
    v = (x @ params["wv"]).reshape(n, n_heads, d_h).transpose(1, 0, 2)
    op = attention_op(op_name, d_h)
    y = jax.vmap(op)(q, k, v)  # (H, N, d_h)
    y = y.transpose(1, 0, 2).reshape(n, d_model)
    return y @ params["wo"]


def transformer_block(
    x: jnp.ndarray, params: dict, op_name: str, n_heads: int
) -> jnp.ndarray:
    """Pre-norm block: x + MHA(LN(x)); x + MLP(LN(x))."""
    h = x + multi_head_attention(
        _layer_norm(x, params["ln1_g"], params["ln1_b"]), params, op_name, n_heads
    )
    m = _layer_norm(h, params["ln2_g"], params["ln2_b"])
    m = jax.nn.gelu(m @ params["w1"] + params["b1"]) @ params["w2"] + params["b2"]
    return h + m


def make_block_fn(op_name: str, d_model: int, n_heads: int, d_ff: int, seed: int = 11):
    """Jittable transformer block with baked weights: (x,) -> (y,)."""
    params = init_block_params(seed, d_model, n_heads, d_ff)

    def fn(x):
        return (transformer_block(x, params, op_name, n_heads),)

    return fn
