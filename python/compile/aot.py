"""AOT compile path: lower L2 jax functions to HLO *text* + golden I/O.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Outputs, per artifact ``<name>``:

- ``artifacts/<name>.hlo.txt``    — the HLO module the Rust runtime compiles
- ``artifacts/<name>.golden.txt`` — seeded inputs + oracle outputs so Rust
                                    integration tests can validate numerics
- ``artifacts/manifest.txt``      — one line per artifact: name, kind,
                                    operator, N, d, input arity/shapes

Run via ``make artifacts`` (no-op if inputs are unchanged).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Context lengths lowered for *real* PJRT execution. Longer contexts
# (1024..8192, paper Tables II-VIII) run on the NPU simulator — compiling
# interpret-mode Pallas HLO at N=8192 is neither needed nor cheap.
OPERATOR_CONTEXTS = (128, 256, 512)
BLOCK_CONTEXTS = (128, 256)
BLOCK_D_MODEL = 256
BLOCK_N_HEADS = 4
BLOCK_D_FF = 512
GOLDEN_SEED = 1234


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the 0.5.1-safe path).

    ``print_large_constants=True`` is essential: the default printer elides
    big constant literals as ``constant({...})``, which the text parser on
    the Rust side silently fills with zeros — baked model weights would
    vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _write_tensor(f, arr: np.ndarray) -> None:
    arr = np.asarray(arr)
    f.write(f"tensor {arr.ndim} {' '.join(str(s) for s in arr.shape)}\n")
    f.write(" ".join(f"{x:.9g}" for x in arr.reshape(-1)) + "\n")


def _write_golden(path: str, name: str, inputs, outputs) -> None:
    with open(path, "w") as f:
        f.write(f"artifact {name}\n")
        f.write(f"inputs {len(inputs)}\n")
        for a in inputs:
            _write_tensor(f, a)
        f.write(f"outputs {len(outputs)}\n")
        for a in outputs:
            _write_tensor(f, a)


def _lower_artifact(out_dir: str, name: str, fn, example_inputs) -> dict:
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in example_inputs]
    lowered = jax.jit(fn).lower(*specs)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(to_hlo_text(lowered))
    outputs = fn(*example_inputs)
    _write_golden(
        os.path.join(out_dir, f"{name}.golden.txt"), name, example_inputs, outputs
    )
    return {
        "name": name,
        "inputs": [tuple(a.shape) for a in example_inputs],
        "outputs": [tuple(np.asarray(a).shape) for a in outputs],
    }


def _rand(rng: np.random.RandomState, *shape) -> jnp.ndarray:
    return jnp.asarray(rng.randn(*shape) * 0.5, jnp.float32)


def build_all(out_dir: str, quick: bool = False) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.RandomState(GOLDEN_SEED)
    manifest: list[dict] = []

    op_contexts = OPERATOR_CONTEXTS if not quick else (128,)
    blk_contexts = BLOCK_CONTEXTS if not quick else (128,)
    ops = model.OPERATOR_NAMES if not quick else ("causal", "linear")

    for op in ops:
        for n in op_contexts:
            name = f"{op}_n{n}_d{model.D_HEAD}"
            fn = model.make_operator_fn(op)
            q, k, v = (_rand(rng, n, model.D_HEAD) for _ in range(3))
            entry = _lower_artifact(out_dir, name, fn, (q, k, v))
            entry.update(kind="operator", operator=op, n=n, d=model.D_HEAD)
            manifest.append(entry)
            print(f"  lowered {name}")

    for op in ops:
        for n in blk_contexts:
            name = f"block_{op}_n{n}_dm{BLOCK_D_MODEL}"
            fn = model.make_block_fn(op, BLOCK_D_MODEL, BLOCK_N_HEADS, BLOCK_D_FF)
            x = _rand(rng, n, BLOCK_D_MODEL)
            entry = _lower_artifact(out_dir, name, fn, (x,))
            entry.update(kind="block", operator=op, n=n, d=BLOCK_D_MODEL)
            manifest.append(entry)
            print(f"  lowered {name}")

    # Decode-phase artifacts (one autoregressive step, §II-A Eq. 3): the
    # causal step over a 512-token KV cache and the recurrent linear step.
    if not quick:
        from .kernels import decode as decode_kernels

        n_cache = 512
        name = f"decode_causal_n{n_cache}_d{model.D_HEAD}"
        fn = lambda q, k, v: (decode_kernels.causal_decode(q, k, v),)
        q1 = _rand(rng, 1, model.D_HEAD)
        kc, vc = _rand(rng, n_cache, model.D_HEAD), _rand(rng, n_cache, model.D_HEAD)
        entry = _lower_artifact(out_dir, name, fn, (q1, kc, vc))
        entry.update(kind="decode", operator="causal", n=n_cache, d=model.D_HEAD)
        manifest.append(entry)
        print(f"  lowered {name}")

        name = f"decode_linear_d{model.D_HEAD}_r{model.D_STATE}"
        proj = model._linear_proj(model.D_HEAD, model.D_STATE)
        step = lambda q, k, v, s, z: decode_kernels.linear_decode_step(
            q, k, v, proj, s, z
        )
        s0 = jnp.zeros((model.D_STATE, model.D_HEAD), jnp.float32)
        z0 = jnp.zeros((1, model.D_STATE), jnp.float32)
        entry = _lower_artifact(
            out_dir,
            name,
            step,
            (q1, _rand(rng, 1, model.D_HEAD), _rand(rng, 1, model.D_HEAD), s0, z0),
        )
        entry.update(kind="decode", operator="linear", n=1, d=model.D_HEAD)
        manifest.append(entry)
        print(f"  lowered {name}")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for e in manifest:
            ins = ";".join(",".join(str(d) for d in s) for s in e["inputs"])
            outs = ";".join(",".join(str(d) for d in s) for s in e["outputs"])
            f.write(
                f"{e['name']} kind={e['kind']} op={e['operator']} n={e['n']} "
                f"d={e['d']} inputs={ins} outputs={outs}\n"
            )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--quick", action="store_true", help="small artifact set for CI smoke"
    )
    args = ap.parse_args()
    manifest = build_all(args.out, quick=args.quick)
    print(f"wrote {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
