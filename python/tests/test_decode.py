"""Decode-step kernels vs prefill oracles: token-by-token decoding must
reproduce the prefill outputs row for row (the §II-A prefill/decode
equivalence)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode, ref


def _qkv(n, d, seed=0):
    rng = np.random.RandomState(seed)
    return tuple(jnp.asarray(rng.randn(n, d) * 0.5, jnp.float32) for _ in range(3))


def _proj(d, r, seed=7):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(d, r) * 0.3, jnp.float32)


def test_causal_decode_matches_prefill_last_row():
    q, k, v = _qkv(128, 64)
    prefill = ref.causal_attention(q, k, v)
    step = decode.causal_decode(q[-1:], k, v)
    np.testing.assert_allclose(
        np.asarray(step[0]), np.asarray(prefill[-1]), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("t", [0, 1, 17, 63])
def test_causal_decode_matches_prefill_any_position(t):
    q, k, v = _qkv(64, 32, seed=3)
    prefill = ref.causal_attention(q, k, v)
    step = decode.causal_decode(q[t : t + 1], k[: t + 1], v[: t + 1])
    np.testing.assert_allclose(
        np.asarray(step[0]), np.asarray(prefill[t]), rtol=2e-5, atol=2e-5
    )


def test_linear_decode_sequence_matches_prefill():
    """Running the recurrent step over the whole sequence must equal the
    parallel (cumsum) oracle — the linear-attention duality."""
    n, d, r = 96, 32, 16
    q, k, v = _qkv(n, d, seed=5)
    p = _proj(d, r)
    want = np.asarray(ref.linear_attention(q, k, v, p))
    s = jnp.zeros((r, d), jnp.float32)
    z = jnp.zeros((1, r), jnp.float32)
    got = []
    for t in range(n):
        y, s, z = decode.linear_decode_step(
            q[t : t + 1], k[t : t + 1], v[t : t + 1], p, s, z
        )
        got.append(np.asarray(y[0]))
    np.testing.assert_allclose(np.stack(got), want, rtol=5e-4, atol=5e-4)


def test_linear_decode_state_is_cumulative():
    d, r = 32, 8
    q, k, v = _qkv(4, d, seed=9)
    p = _proj(d, r)
    s = jnp.zeros((r, d), jnp.float32)
    z = jnp.zeros((1, r), jnp.float32)
    _, s1, z1 = decode.linear_decode_step(q[:1], k[:1], v[:1], p, s, z)
    _, s2, z2 = decode.linear_decode_step(q[1:2], k[1:2], v[1:2], p, s1, z1)
    assert float(jnp.sum(jnp.abs(s2))) > float(jnp.sum(jnp.abs(s1)))
    assert float(z2.sum()) > float(z1.sum())


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([16, 48, 96]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_hypothesis_causal_decode(n, d, seed):
    q, k, v = _qkv(n, d, seed=seed)
    prefill = ref.causal_attention(q, k, v)
    t = n - 1
    step = decode.causal_decode(q[t : t + 1], k, v)
    np.testing.assert_allclose(
        np.asarray(step[0]), np.asarray(prefill[t]), rtol=1e-4, atol=1e-4
    )
