"""L2 model tests: operator wrappers, MHA, transformer block shapes/semantics."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _x(n, d, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n, d) * 0.5, jnp.float32)


@pytest.mark.parametrize("op", model.OPERATOR_NAMES)
def test_operator_fn_shape(op):
    fn = model.make_operator_fn(op)
    q, k, v = _x(128, 64, 1), _x(128, 64, 2), _x(128, 64, 3)
    (y,) = fn(q, k, v)
    assert y.shape == (128, 64)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_operator_fn_matches_ref_causal():
    fn = model.make_operator_fn("causal")
    q, k, v = _x(256, 64, 4), _x(256, 64, 5), _x(256, 64, 6)
    np.testing.assert_allclose(
        np.asarray(fn(q, k, v)[0]),
        np.asarray(ref.causal_attention(q, k, v)),
        rtol=2e-5,
        atol=2e-5,
    )


@pytest.mark.parametrize("op", model.OPERATOR_NAMES)
def test_block_shape_and_finite(op):
    fn = model.make_block_fn(op, d_model=256, n_heads=4, d_ff=512)
    x = _x(128, 256, 7)
    (y,) = fn(x)
    assert y.shape == (128, 256)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_block_is_deterministic():
    fn = model.make_block_fn("causal", 256, 4, 512)
    x = _x(128, 256, 8)
    (a,) = fn(x)
    (b,) = fn(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_block_params_seeded():
    p1 = model.init_block_params(11, 256, 4, 512)
    p2 = model.init_block_params(11, 256, 4, 512)
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))


def test_block_causality():
    """Block outputs at positions <= t must not depend on tokens > t."""
    fn = model.make_block_fn("causal", 256, 4, 512)
    x = _x(128, 256, 9)
    t = 50
    x2 = x.at[t + 1 :].set(3.0)
    (a,) = fn(x)
    (b,) = fn(x2)
    np.testing.assert_allclose(
        np.asarray(a[: t + 1]), np.asarray(b[: t + 1]), rtol=1e-4, atol=1e-4
    )


def test_mha_head_split_consistency():
    """One head of MHA with identity projections reduces to the raw op."""
    n, d_model, h = 128, 64, 1
    params = model.init_block_params(0, d_model, h, 128)
    eye = jnp.eye(d_model, dtype=jnp.float32)
    params = dict(params, wq=eye, wk=eye, wv=eye, wo=eye)
    x = _x(n, d_model, 10)
    got = model.multi_head_attention(x, params, "causal", h)
    want = ref.causal_attention(x, x, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_attention_op_unknown_raises():
    with pytest.raises(ValueError):
        model.attention_op("nonexistent")


def test_block_jit_roundtrip():
    """The exact function aot.py lowers must be jittable with static shapes."""
    fn = model.make_block_fn("linear", 256, 4, 512)
    x = _x(128, 256, 12)
    (eager,) = fn(x)
    (jitted,) = jax.jit(fn)(x)
    np.testing.assert_allclose(
        np.asarray(eager), np.asarray(jitted), rtol=1e-5, atol=1e-5
    )
