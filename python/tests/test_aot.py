"""AOT pipeline tests: HLO text validity, golden files, manifest integrity."""

from __future__ import annotations

import os
import tempfile

import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _have_artifacts() -> bool:
    return os.path.exists(os.path.join(ARTIFACTS, "manifest.txt"))


def test_to_hlo_text_smoke():
    """Lower a trivial jitted fn; the text must parse as an HLO module."""
    import jax
    import jax.numpy as jnp

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_quick_build_roundtrip(tmp_path):
    """--quick build produces parseable manifest + goldens that agree with a
    fresh forward pass (determinism of the baked weights)."""
    manifest = aot.build_all(str(tmp_path), quick=True)
    assert len(manifest) >= 3
    for e in manifest:
        assert os.path.exists(tmp_path / f"{e['name']}.hlo.txt")
        golden = tmp_path / f"{e['name']}.golden.txt"
        assert os.path.exists(golden)
        lines = golden.read_text().split("\n")
        assert lines[0] == f"artifact {e['name']}"


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
def test_manifest_names_match_files():
    with open(os.path.join(ARTIFACTS, "manifest.txt")) as f:
        for line in f:
            name = line.split()[0]
            assert os.path.exists(os.path.join(ARTIFACTS, f"{name}.hlo.txt")), name
            assert os.path.exists(os.path.join(ARTIFACTS, f"{name}.golden.txt")), name


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
def test_golden_outputs_reproducible():
    """Re-running the model on golden inputs reproduces golden outputs —
    guards against weight-seeding drift between aot runs."""
    path = os.path.join(ARTIFACTS, "causal_n128_d64.golden.txt")
    with open(path) as f:
        lines = f.read().split("\n")
    assert lines[0] == "artifact causal_n128_d64"
    idx = 2
    tensors = []
    for _ in range(4):  # 3 inputs + (after 'outputs 1' header) 1 output
        if lines[idx].startswith(("inputs", "outputs")):
            idx += 1
        header = lines[idx].split()
        assert header[0] == "tensor"
        rank = int(header[1])
        shape = tuple(int(x) for x in header[2 : 2 + rank])
        vals = np.fromstring(lines[idx + 1], sep=" ", dtype=np.float32)
        tensors.append(vals.reshape(shape))
        idx += 2
    q, k, v, want = tensors
    fn = model.make_operator_fn("causal")
    (got,) = fn(q, k, v)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
