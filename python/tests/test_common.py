"""Kernel block-policy tests: the DESIGN.md §Hardware-Adaptation contract —
blocks are MXU/systolic-tile multiples and every grid step's working set
fits the 4 MB scratchpad (VMEM analogue)."""

from __future__ import annotations

import jax.numpy as jnp
import pytest

from compile.kernels import common


def test_tile_is_systolic_edge():
    assert common.TILE == 128


def test_q_block_caps_at_context():
    assert common.q_block(64) == 64
    assert common.q_block(128) == 128
    assert common.q_block(8192) == 128


@pytest.mark.parametrize("n", [128, 256, 512])
def test_causal_kernel_vmem_budget(n):
    """One causal grid step: q block + full K/V stream + score block, f32.
    Must fit the 4 MiB scratchpad with double-buffering headroom (<50%)."""
    d = 64
    bq = common.q_block(n)
    fp = common.vmem_footprint_bytes(
        ((bq, d), jnp.float32),  # q block
        ((n, d), jnp.float32),  # K
        ((n, d), jnp.float32),  # V
        ((bq, n), jnp.float32),  # scores
        ((bq, d), jnp.float32),  # out
    )
    assert fp < common.SCRATCHPAD_BYTES // 2, f"N={n}: {fp} bytes"


def test_toeplitz_window_vmem_independent_of_n():
    d, band = 64, 128
    bq = common.q_block(8192)
    window = band + bq
    fp = common.vmem_footprint_bytes(
        ((bq, d), jnp.float32),
        ((window, d), jnp.float32),
        ((window, d), jnp.float32),
        ((bq, window), jnp.float32),
        ((bq, d), jnp.float32),
    )
    # Constant in N and tiny: the whole point of the banded schedule.
    assert fp < common.SCRATCHPAD_BYTES // 8


def test_linear_chunk_state_vmem():
    d, r, c = 64, 16, 128
    fp = common.vmem_footprint_bytes(
        ((c, d), jnp.float32),
        ((c, d), jnp.float32),
        ((c, d), jnp.float32),
        ((d, r), jnp.float32),
        ((r, d), jnp.float32),  # state S
        ((c, c), jnp.float32),  # intra-chunk scores
        ((c, d), jnp.float32),  # out
    )
    assert fp < common.SCRATCHPAD_BYTES // 16, "chunk step is tiny by design"


def test_footprint_arithmetic():
    fp = common.vmem_footprint_bytes(((10, 10), jnp.float32), ((5,), jnp.bfloat16))
    assert fp == 10 * 10 * 4 + 5 * 2


def test_interpret_mode_is_forced():
    # CPU PJRT cannot run Mosaic custom-calls: the flag must stay on.
    assert common.INTERPRET is True
