"""Kernel-vs-oracle correctness: the CORE numeric signal of the stack.

Every Pallas kernel (interpret=True) must match its pure-jnp oracle in
``compile.kernels.ref``. Fixed-shape tests pin the paper's configurations;
hypothesis sweeps shapes/dtypes per the repo test policy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    causal,
    fourier,
    linear,
    ref,
    retentive,
    toeplitz,
)

jax.config.update("jax_enable_x64", False)


def _qkv(n: int, d: int, seed: int = 0, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    return tuple(jnp.asarray(rng.randn(n, d) * 0.5, dtype) for _ in range(3))


def _proj(d: int, r: int, seed: int = 7):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(d, r) * 0.3, jnp.float32)


def _assert_close(a, b, rtol=2e-5, atol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Fixed-shape (paper configuration) tests
# ---------------------------------------------------------------------------

PAPER_SHAPES = [(128, 64), (256, 64), (512, 64)]


@pytest.mark.parametrize("n,d", PAPER_SHAPES)
def test_causal_matches_oracle(n, d):
    q, k, v = _qkv(n, d)
    _assert_close(causal.causal_attention(q, k, v), ref.causal_attention(q, k, v))


@pytest.mark.parametrize("n,d", PAPER_SHAPES)
def test_retentive_matches_oracle(n, d):
    q, k, v = _qkv(n, d, seed=1)
    _assert_close(
        retentive.retentive_attention(q, k, v, gamma=0.97),
        ref.retentive_attention(q, k, v, gamma=0.97),
    )


@pytest.mark.parametrize("n,d", PAPER_SHAPES)
def test_toeplitz_matches_banded_oracle(n, d):
    q, k, v = _qkv(n, d, seed=2)
    _assert_close(
        toeplitz.toeplitz_attention(q, k, v, band=128, gamma=0.9),
        ref.toeplitz_banded_attention(q, k, v, band=128, gamma=0.9),
    )


@pytest.mark.parametrize("n,d", PAPER_SHAPES)
def test_linear_matches_oracle(n, d):
    q, k, v = _qkv(n, d, seed=3)
    p = _proj(d, 16)
    _assert_close(
        linear.linear_attention(q, k, v, p),
        ref.linear_attention(q, k, v, p),
        rtol=2e-4,
        atol=2e-4,
    )


@pytest.mark.parametrize("n,d", PAPER_SHAPES)
def test_fourier_matches_oracle(n, d):
    q, k, v = _qkv(n, d, seed=4)
    _assert_close(
        fourier.fourier_attention(q, k, v),
        ref.fourier_attention(q, k, v),
        rtol=2e-4,
        atol=2e-4,
    )


# ---------------------------------------------------------------------------
# Semantics / invariants
# ---------------------------------------------------------------------------


def test_causal_first_row_is_v0():
    """Position 0 can only attend to itself: y_0 == v_0."""
    q, k, v = _qkv(128, 32, seed=5)
    out = causal.causal_attention(q, k, v)
    _assert_close(out[0], v[0])


def test_causality_no_future_leak():
    """Perturbing tokens at positions > t must not change outputs <= t."""
    q, k, v = _qkv(256, 64, seed=6)
    t = 100
    k2 = k.at[t + 1 :].set(9.0)
    v2 = v.at[t + 1 :].set(-9.0)
    for fn in (
        causal.causal_attention,
        retentive.retentive_attention,
        lambda a, b, c: toeplitz.toeplitz_attention(a, b, c, band=64),
        lambda a, b, c: linear.linear_attention(a, b, c, _proj(64, 16)),
    ):
        _assert_close(fn(q, k, v)[: t + 1], fn(q, k2, v2)[: t + 1], rtol=1e-4, atol=1e-4)


def test_retentive_reduces_to_causal_at_gamma_one():
    """gamma = 1 removes the decay: retentive == full causal."""
    q, k, v = _qkv(128, 64, seed=8)
    _assert_close(
        retentive.retentive_attention(q, k, v, gamma=1.0 - 1e-12),
        ref.causal_attention(q, k, v),
        rtol=1e-4,
        atol=1e-4,
    )


def test_toeplitz_full_band_matches_full_oracle():
    """band >= N makes the banded kernel exact against the full oracle."""
    q, k, v = _qkv(128, 64, seed=9)
    _assert_close(
        toeplitz.toeplitz_attention(q, k, v, band=128, gamma=0.9),
        ref.toeplitz_attention(q, k, v, gamma=0.9),
    )


def test_attention_rows_are_convex_combinations():
    """Softmax rows sum to 1 => outputs stay in conv-hull bounds of V."""
    q, k, v = _qkv(256, 64, seed=10)
    for fn in (causal.causal_attention, retentive.retentive_attention):
        out = np.asarray(fn(q, k, v))
        assert out.max() <= float(np.max(v)) + 1e-4
        assert out.min() >= float(np.min(v)) - 1e-4


def test_linear_chunk_boundary_consistency():
    """Chunked kernel must be invariant to where chunk boundaries fall:
    N=256 (2 chunks of 128) must equal the oracle's global cumsum."""
    q, k, v = _qkv(256, 64, seed=11)
    p = _proj(64, 16)
    _assert_close(
        linear.linear_attention(q, k, v, p),
        ref.linear_attention(q, k, v, p),
        rtol=2e-4,
        atol=2e-4,
    )


def test_fourier_linearity_in_v():
    """Fourier attention is linear in V: f(q,k,2v) == 2 f(q,k,v)."""
    q, k, v = _qkv(128, 32, seed=12)
    _assert_close(
        fourier.fourier_attention(q, k, 2.0 * v),
        2.0 * fourier.fourier_attention(q, k, v),
        rtol=1e-4,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# Hypothesis shape/dtype sweeps
# ---------------------------------------------------------------------------

_shapes = st.sampled_from([(64, 16), (64, 32), (128, 16), (128, 64), (256, 32)])
_seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=12, deadline=None)
@given(shape=_shapes, seed=_seeds)
def test_hypothesis_causal(shape, seed):
    n, d = shape
    q, k, v = _qkv(n, d, seed=seed % 1000)
    _assert_close(causal.causal_attention(q, k, v), ref.causal_attention(q, k, v))


@settings(max_examples=12, deadline=None)
@given(shape=_shapes, seed=_seeds, gamma=st.floats(min_value=0.8, max_value=0.999))
def test_hypothesis_retentive(shape, seed, gamma):
    n, d = shape
    q, k, v = _qkv(n, d, seed=seed % 1000)
    _assert_close(
        retentive.retentive_attention(q, k, v, gamma=gamma),
        ref.retentive_attention(q, k, v, gamma=gamma),
        rtol=1e-4,
        atol=1e-4,
    )


@settings(max_examples=12, deadline=None)
@given(
    shape=_shapes,
    seed=_seeds,
    band=st.sampled_from([32, 64, 128]),
)
def test_hypothesis_toeplitz(shape, seed, band):
    n, d = shape
    q, k, v = _qkv(n, d, seed=seed % 1000)
    _assert_close(
        toeplitz.toeplitz_attention(q, k, v, band=band),
        ref.toeplitz_banded_attention(q, k, v, band=band),
        rtol=1e-4,
        atol=1e-4,
    )


@settings(max_examples=10, deadline=None)
@given(shape=_shapes, seed=_seeds, r=st.sampled_from([8, 16, 32]))
def test_hypothesis_linear(shape, seed, r):
    n, d = shape
    q, k, v = _qkv(n, d, seed=seed % 1000)
    p = _proj(d, r, seed=seed % 97)
    _assert_close(
        linear.linear_attention(q, k, v, p),
        ref.linear_attention(q, k, v, p),
        rtol=5e-4,
        atol=5e-4,
    )


@settings(max_examples=10, deadline=None)
@given(shape=_shapes, seed=_seeds)
def test_hypothesis_fourier(shape, seed):
    n, d = shape
    q, k, v = _qkv(n, d, seed=seed % 1000)
    _assert_close(
        fourier.fourier_attention(q, k, v),
        ref.fourier_attention(q, k, v),
        rtol=2e-4,
        atol=2e-4,
    )


@settings(max_examples=6, deadline=None)
@given(shape=st.sampled_from([(128, 64), (256, 32)]), seed=_seeds)
def test_hypothesis_bfloat16_causal(shape, seed):
    """bfloat16 inputs: kernel upcasts to f32 internally; loose tolerance."""
    n, d = shape
    q, k, v = _qkv(n, d, seed=seed % 1000, dtype=jnp.bfloat16)
    got = causal.causal_attention(q, k, v).astype(jnp.float32)
    want = ref.causal_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    _assert_close(got, want, rtol=5e-2, atol=5e-2)
