//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate links the `xla_extension` native library, which is not
//! available in this container. This stub keeps the exact API surface
//! `runtime/client.rs` compiles against and **fails fast** at
//! [`PjRtClient::cpu`], so every PJRT-backed path degrades to a clean
//! runtime error ("PJRT unavailable ...") instead of a link failure.
//!
//! The serving stack is built for this: the router falls back to the NPU
//! simulator whenever artifacts/PJRT are unavailable, and the runtime
//! integration tests skip when `artifacts/manifest.txt` is absent.

use std::fmt;
use std::path::Path;

/// Stub error: every fallible call returns this.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub `Result` alias matching the real crate's signatures.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "PJRT unavailable: built against the vendored xla stub (no \
         xla_extension native library in this environment); cannot {what}"
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    /// Real crate: create a CPU PJRT client. Stub: always errors.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("create a CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile an executable"))
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable("parse HLO text"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetch a result buffer"))
    }
}

/// Host literal (stub).
pub struct Literal(());

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("reshape a literal"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("decompose a tuple literal"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("read literal values"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"), "{err}");
    }

    #[test]
    fn literal_helpers_compile_and_err() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
