//! Offline drop-in subset of the `anyhow` crate.
//!
//! The container building this repo has no crates.io access, so this
//! vendored shim provides exactly the surface the codebase uses:
//!
//! - [`Error`] / [`Result`] — a string-backed error with an optional cause
//!   chain,
//! - [`anyhow!`] / [`bail!`] — ad-hoc error construction macros,
//! - [`Context`] — `.context(..)` / `.with_context(..)` on any `Result`
//!   whose error converts into [`Error`],
//! - a blanket `From<E: std::error::Error>` so `?` works on `io::Error`,
//!   `ParseIntError`, etc.
//!
//! Semantics match real `anyhow` where this repo depends on them:
//! `Display` shows the outermost message, `Debug` shows the cause chain.

use std::fmt::{self, Debug, Display};

/// String-backed error with an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` specialized to [`Error`], as in real `anyhow`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error under a new outermost context message.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    /// Innermost (root) cause message.
    pub fn root_cause(&self) -> &Error {
        self.chain().last().expect("chain is never empty")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain().skip(1).enumerate() {
                write!(f, "\n    {i}: {}", cause.msg)?;
            }
        }
        Ok(())
    }
}

// `?` on any std error. Sound for the same reason real anyhow's blanket impl
// is: `Error` itself does not implement `std::error::Error`, so this cannot
// overlap the identity `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `.context(..)` / `.with_context(..)` extension, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error, if any.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Attach a lazily-built context message to the error, if any.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message literal (with inline format
/// captures), a displayable expression, or a format string + args.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`anyhow!`]-constructed error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ok() -> Result<u32> {
        let n: u32 = "42".parse()?; // From<ParseIntError>
        Ok(n)
    }

    fn parse_err() -> Result<u32> {
        let n: u32 = "nope".parse()?;
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_ok().unwrap(), 42);
        let e = parse_err().unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("x = {x}");
        assert_eq!(b.to_string(), "x = 7");
        let c = anyhow!("{} {}", "two", "args");
        assert_eq!(c.to_string(), "two args");
        let s = String::from("owned");
        let d = anyhow!(s);
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 1);
            }
            Ok(0)
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "boom 1");
    }

    #[test]
    fn context_wraps_and_chains() {
        let base: Result<()> = Err(anyhow!("root"));
        let e = base.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause().to_string(), "root");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("root"), "{dbg}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::num::ParseIntError> = "5".parse();
        let got = ok.with_context(|| -> String { unreachable!("not called on Ok") });
        assert_eq!(got.unwrap(), 5);
        let bad: std::result::Result<u32, std::num::ParseIntError> = "x".parse();
        let e = bad.with_context(|| format!("parsing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "parsing x");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }
}
