//! Co-design explorer: the §V design-space walk-through.
//!
//! Sweeps the knobs the paper's Discussion identifies — prefill chunk
//! size, state dimension, concat offload, double-buffering — and prints
//! the deployment recipe a hardware-aware model would adopt.
//!
//! Run: `cargo run --release --example codesign_explorer`

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::coordinator::chunking;
use npuperf::coordinator::state::{SessionKind, StateManager};
use npuperf::{npu, ops};

fn latency(op: OperatorKind, n: usize, d_state: usize, sim: &SimConfig) -> f64 {
    let hw = NpuConfig::default();
    let spec = WorkloadSpec::new(op, n).with_d_state(d_state);
    npu::run(&ops::lower(&spec, &hw, sim), &hw, sim).latency_ms()
}

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();

    // ---- 1. chunked prefill (§V: optimum 2048, 8x memory reduction) ----
    println!("=== chunked prefill, N = 32768 ===");
    for c in [512usize, 1024, 2048, 4096] {
        let p = chunking::plan(32_768, c, 64, &hw);
        println!(
            "  C={:<5} peak={:<10} latency={:>8.2} ms{}",
            c,
            npuperf::util::fmt::bytes(p.peak_bytes),
            p.latency_ms,
            if p.overflows { "  [scratchpad overflow]" } else { "" }
        );
    }
    let best = chunking::optimal_chunk(32_768, 64, &hw);
    println!(
        "  -> optimal C={} ; peak-memory reduction {:.1}x vs monolithic\n",
        best.chunk,
        chunking::peak_memory_reduction(32_768, best.chunk, 64)
    );

    // ---- 2. state dimension (§V: d_state 32 sweet spot) ----------------
    println!("=== d_state sweep at N=4096 (latency ms) ===");
    println!("{:<10} {:>8} {:>8} {:>8} {:>8}", "operator", "16", "32", "64", "128");
    for op in [OperatorKind::Linear, OperatorKind::Toeplitz, OperatorKind::Fourier] {
        let l: Vec<f64> =
            [16, 32, 64, 128].iter().map(|&d| latency(op, 4096, d, &sim)).collect();
        println!(
            "{:<10} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            op.paper_name(),
            l[0],
            l[1],
            l[2],
            l[3]
        );
    }

    // ---- 3. concat offload + double buffering ---------------------------
    println!("\n=== DMA management ablations at N=4096 ===");
    let base = latency(OperatorKind::Fourier, 4096, 16, &sim);
    let off = latency(OperatorKind::Fourier, 4096, 16, &sim.clone().with_offload(true));
    println!(
        "Fourier concat offload to CPU: {base:.2} -> {off:.2} ms ({:+.1}%; paper: -32%)",
        100.0 * (off - base) / base
    );
    let db = latency(OperatorKind::Toeplitz, 8192, 16, &sim);
    let nodb =
        latency(OperatorKind::Toeplitz, 8192, 16, &sim.clone().with_double_buffer(false));
    println!(
        "Toeplitz double-buffering:     {nodb:.2} -> {db:.2} ms ({:+.1}%)",
        100.0 * (db - nodb) / nodb
    );

    // ---- 4. memory-state tradeoff (Fig 1) -------------------------------
    println!("\n=== persistent-state footprint at 100K tokens (Fig 1) ===");
    let mut m = StateManager::new(u64::MAX);
    for (id, op) in OperatorKind::ALL.iter().enumerate() {
        m.open(id as u64, *op, 64, 16);
        m.append(id as u64, 100_000);
        println!(
            "  {:<12} {:>12}   ({:?})",
            op.paper_name(),
            npuperf::util::fmt::bytes(m.session_bytes(id as u64).unwrap()),
            SessionKind::for_operator(*op)
        );
    }

    // ---- 5. the recipe ---------------------------------------------------
    println!("\n=== co-design recipe (paper §V) ===");
    println!("  - prefill in {}-token chunks (scratchpad-bounded)", best.chunk);
    println!("  - prefer Toeplitz/Linear beyond ~1K context; avoid Fourier");
    println!("  - keep element-wise epilogues fused or SHAVE becomes the wall");
    println!("  - offload state concats to the host CPU when DMA-bound");
}
