use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::{npu, ops};
use std::time::Instant;
fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    for op in OperatorKind::ALL {
        let spec = WorkloadSpec::new(op, 8192);
        let t0 = Instant::now();
        let g = ops::lower(&spec, &hw, &sim);
        let t_lower = t0.elapsed();
        let t1 = Instant::now();
        let r = npu::run(&g, &hw, &sim);
        let t_sim = t1.elapsed();
        println!("{:<10} nodes={:<7} lower={:>8.1?} sim={:>8.1?} (modeled {:.1} ms)",
                 op.name(), g.len(), t_lower, t_sim, r.latency_ms());
    }
}
