//! End-to-end serving driver — the full three-layer stack on a real
//! workload (the repo's headline E2E validation, see EXPERIMENTS.md §E2E).
//!
//! Flow:
//!   1. Golden-validate every AOT artifact through PJRT (numerics gate:
//!      JAX/Pallas oracle == Rust execution).
//!   2. Serve a mixed stream of batched requests through the coordinator —
//!      short contexts execute *real* transformer-block and operator HLO
//!      on the PJRT CPU client; long contexts are planned on the simulated
//!      NPU (the paper's regime).
//!   3. Report per-operator latency/throughput and the serving metrics.
//!
//! Run: `make artifacts && cargo run --release --example long_context_serving`

use npuperf::config::{OperatorKind, WorkloadSpec};
use npuperf::coordinator::{BackendKind, Coordinator, CoordinatorConfig, Request};
use npuperf::runtime::{Golden, HloRuntime, Manifest, Tensor};
use npuperf::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // ---- 1. numerics gate ---------------------------------------------
    println!("=== phase 1: validating artifacts against JAX goldens ===");
    let mut rt = HloRuntime::new(&dir)?;
    let names: Vec<String> = rt.manifest().entries.iter().map(|e| e.name.clone()).collect();
    let mut worst = 0.0f32;
    for name in &names {
        let diff = rt.validate(name)?;
        worst = worst.max(diff);
    }
    println!("validated {} artifacts on PJRT ({}), worst max|Δ| = {worst:.2e}",
             names.len(), rt.platform());
    assert!(worst < 5e-3, "numerics gate failed");
    drop(rt); // release the client before the coordinator spawns its own

    // ---- 2. batched serving -------------------------------------------
    println!("\n=== phase 2: serving a mixed request stream ===");
    let coord = Coordinator::new(CoordinatorConfig {
        artifact_dir: Some(dir.clone()),
        warmup: true, // pre-compile all executables: steady-state serving
        ..CoordinatorConfig::default()
    })?;

    // Real inputs for the PJRT paths, drawn from the goldens.
    let manifest = Manifest::load(&dir)?;
    let golden_inputs = |op: OperatorKind, n: usize| -> Option<Vec<Tensor>> {
        let name = format!("{}_n{n}_d64", op.name());
        Golden::load(manifest.golden_path(&name)).ok().map(|g| g.inputs)
    };

    let mut reqs = Vec::new();
    let mut session = 0u64;
    for round in 0..5 {
        for op in OperatorKind::ALL {
            for n in [128usize, 256, 512, 2048, 8192] {
                session += 1;
                let inputs = if n <= 512 { golden_inputs(op, n) } else { None };
                let _ = round;
                reqs.push(Request { spec: WorkloadSpec::new(op, n), session, inputs });
            }
        }
    }
    let total = reqs.len();
    let t0 = std::time::Instant::now();
    let responses = coord.submit_all(reqs)?;
    let wall = t0.elapsed().as_secs_f64();

    // ---- 3. report ------------------------------------------------------
    println!("served {total} requests in {wall:.2} s  ->  {:.1} req/s", total as f64 / wall);
    let mut by_backend = [Summary::new(), Summary::new()];
    for r in &responses {
        let idx = if r.backend == BackendKind::Pjrt { 0 } else { 1 };
        by_backend[idx].push(r.backend_ns / 1e6);
    }
    println!(
        "PJRT (real execution):   {:>3} reqs  mean {:.3} ms  p99 {:.3} ms",
        by_backend[0].len(),
        by_backend[0].mean(),
        by_backend[0].percentile(99.0)
    );
    println!(
        "Simulated (NPU model):   {:>3} reqs  modeled mean {:.3} ms",
        by_backend[1].len(),
        by_backend[1].mean()
    );
    println!("\n{}", coord.metrics_snapshot()?);

    // Sanity: real outputs flowed through the PJRT path.
    let with_outputs = responses.iter().filter(|r| r.outputs.is_some()).count();
    println!("responses carrying real tensors: {with_outputs}");
    assert!(with_outputs > 0);
    Ok(())
}
