//! Capacity planner: sweep context length × operator and print the
//! maximum number of concurrently *resident* sessions the paged
//! session-memory pool sustains — the paper's quadratic-vs-constant
//! state divergence (Fig 1) expressed as a serving-capacity number
//! instead of a latency number.
//!
//! Run: `cargo run --release --example capacity_planner`

use npuperf::config::{NpuConfig, SimConfig, WorkloadSpec};
use npuperf::memory::MemoryConfig;
use npuperf::ops::registry;
use npuperf::util::fmt;

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    // beta_eff from the same calibration the roofline uses, so spill
    // pricing here matches what the serve loop charges.
    let mem = MemoryConfig::calibrated(&hw, &sim);
    println!(
        "session-state pool: {} ({} pages of {}), spills at {:.2} GB/s effective DMA\n",
        fmt::bytes(mem.pool_bytes),
        mem.pool_pages(),
        fmt::bytes(mem.page_bytes),
        mem.beta_eff_gbps
    );

    let contexts = [1024usize, 4096, 16384, 65536, 262144];
    let cap = |name: &str, n: usize| -> u64 {
        let op = registry::global().get(name).expect("builtin");
        mem.max_sessions(op.state_footprint(&WorkloadSpec::new(op.kind(), n), n))
    };

    print!("{:<18}", "operator");
    for n in contexts {
        print!("{:>12}", format!("N={n}"));
    }
    println!("  state growth");
    for op in registry::global().iter() {
        print!("{:<18}", op.name());
        for n in contexts {
            let fp = op.state_footprint(&WorkloadSpec::new(op.kind(), n), n);
            print!("{:>12}", mem.max_sessions(fp));
        }
        println!("  {}", op.complexity());
    }

    let (short, long) = (cap("causal", contexts[0]), cap("causal", *contexts.last().unwrap()));
    println!(
        "\nFull Causal max-session capacity collapses {}x from N={} to N={};",
        short / long.max(1),
        contexts[0],
        contexts.last().unwrap()
    );
    println!("retention/SSM state and the banded ring buffer hold capacity flat,");
    println!("which is the co-design argument for sub-quadratic operators at scale.");
    assert!(short > 8 * long, "divergence must show up ({short} vs {long})");
    assert_eq!(cap("retentive", contexts[0]), cap("retentive", *contexts.last().unwrap()));
}
