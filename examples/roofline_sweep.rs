//! Roofline sweep: place every operator on the effective-ceiling roofline
//! across a range of context lengths — extends the paper's single-point
//! Fig 7 into a trajectory view (how intensity and achieved GOP/s move as
//! context grows).
//!
//! Run: `cargo run --release --example roofline_sweep`

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::model::{calibrate, Roofline};
use npuperf::{npu, ops};

fn main() {
    let hw = NpuConfig::default();
    let sim = SimConfig::default();
    let ceilings = calibrate(&hw, &sim);
    let roofline = Roofline::new(ceilings);

    println!(
        "effective roofline: pi_eff={:.0} GOP/s, beta_eff={:.2} GB/s, I_crit={:.0} Op/B\n",
        ceilings.pi_eff_gops,
        ceilings.beta_eff_gbps,
        ceilings.i_crit()
    );
    println!(
        "{:<12} {:>6} {:>12} {:>14} {:>12} {:>10}",
        "operator", "N", "I (Op/B)", "meas (GOP/s)", "bound", "of roof"
    );
    for op in OperatorKind::ALL {
        for n in [1024usize, 2048, 4096, 8192] {
            let spec = WorkloadSpec::new(op, n);
            let g = ops::lower(&spec, &hw, &sim);
            let r = npu::run(&g, &hw, &sim);
            let p = roofline.place(&spec, &r, sim.elem_bytes);
            println!(
                "{:<12} {:>6} {:>12.2} {:>14.2} {:>12.1} {:>9.1}%",
                op.paper_name(),
                n,
                p.intensity,
                p.measured_gops,
                p.bound_gops,
                100.0 * p.roof_fraction()
            );
        }
        println!();
    }

    // Single-point paper comparison plot (Fig 7).
    let points: Vec<_> = OperatorKind::ALL
        .iter()
        .map(|&op| {
            let spec = WorkloadSpec::new(op, 4096);
            let g = ops::lower(&spec, &hw, &sim);
            let r = npu::run(&g, &hw, &sim);
            roofline.place(&spec, &r, sim.elem_bytes)
        })
        .collect();
    println!("{}", roofline.ascii_plot(&points, 64, 18));
}
