//! Quickstart: the 60-second tour of the library.
//!
//! 1. Simulate one operator on the modeled NPU and read its report.
//! 2. Calibrate the effective roofline ceilings (paper §IV-A).
//! 3. Ask the cost model which operator to deploy at a given context.
//!
//! Run: `cargo run --release --example quickstart`

use npuperf::config::{NpuConfig, OperatorKind, SimConfig, WorkloadSpec};
use npuperf::coordinator::Router;
use npuperf::model::calibrate;
use npuperf::ops::CausalOperator;
use npuperf::{npu, ops};

fn main() {
    let hw = NpuConfig::default(); // paper Table I testbed
    let sim = SimConfig::default(); // 16-bit, 128-wide tiles, double-buffered

    // --- 1. simulate full causal attention at a long context -----------
    let spec = WorkloadSpec::new(OperatorKind::Causal, 8192);
    let graph = ops::lower(&spec, &hw, &sim);
    let report = npu::run(&graph, &hw, &sim);
    let [dpu, dma, shave] = report.utilization();
    println!("== {spec} ==");
    println!("latency     : {:.2} ms", report.latency_ms());
    println!("bottleneck  : {} (DPU {:.1}% / DMA {:.1}% / SHAVE {:.1}%)",
             report.bottleneck(), dpu * 100.0, dma * 100.0, shave * 100.0);
    println!("pipeline    : {:.1}% stalled on pull", report.stall.stall_frac() * 100.0);
    println!("cache       : {:.1}% efficient, reuse {:.1} ms",
             report.cache.efficiency() * 100.0, report.cache.reuse_ns / 1e6);

    // --- 2. effective ceilings ------------------------------------------
    let c = calibrate(&hw, &sim);
    println!("\n== effective ceilings (paper: pi 500 GOP/s, beta 3.2 GB/s) ==");
    println!("pi_eff  : {:.0} GOP/s ({:.1}% of nominal)", c.pi_eff_gops, c.compute_derate() * 100.0);
    println!("beta_eff: {:.2} GB/s ({:.1}% of nominal)", c.beta_eff_gbps, c.bandwidth_derate() * 100.0);
    println!("I_crit  : {:.0} Ops/Byte", c.i_crit());

    // --- 3. which operator should serve 8K contexts? -------------------
    println!("\n== operator ranking at N=8192 (cost model) ==");
    for (i, (op, ms)) in Router::standard().rank_operators(8192, &hw, &sim).iter().enumerate() {
        println!("{}. {:<12} {:.2} ms", i + 1, op.paper_name(), ms);
    }
}
